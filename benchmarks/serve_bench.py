"""Multi-tenant solve-service load generator: registry economics + SLOs.

Three claims the serving tier makes, priced and asserted here:

* **Warm path** — a pattern-hit numeric refresh onto the resident compiled
  pair is >= 10x faster than standing the planned solver up cold (this is
  the paper's analysis-amortization argument at fleet scale: the registry
  turns streams of same-pattern refactorizations into O(nnz) re-packs);
* **Cold path** — a request for a never-seen pattern is answered by the
  inline serial pair *before* the background planned build completes
  (deterministically pinned with the registry's ``build_gate`` hook), and
  the promoted pair then returns value-identical answers;
* **Residency** — under mixed cold/warm multi-tenant traffic
  (:func:`repro.sparse.serve_traffic`) the registry's resident packed
  bytes never exceed the configured budget, while every request completes.

``--smoke`` asserts all three (CI gate).  ``--json PATH`` writes the
shared-schema perf-trajectory artifact.

Usage::

    python -m benchmarks.serve_bench                    # full-size run
    python -m benchmarks.serve_bench --smoke --json BENCH_serve.json  # CI
"""
from __future__ import annotations

import argparse
import threading
import time

import numpy as np

from repro.compat import enable_x64
from repro.core import CSRMatrix, SpTRSV
from repro.serve import SolverRegistry, SolveService
from repro.sparse import lung2_like, refresh_values, serve_traffic

try:  # runnable both as `python -m benchmarks.serve_bench` and as a file
    from .common import emit, flush_csv, write_bench_json
except ImportError:  # pragma: no cover
    from common import emit, flush_csv, write_bench_json

MIN_WARM_SPEEDUP = 10.0
# generous SLO for shared CI runners: p95 of a drained batch on the small
# mixed-traffic factors; a real deployment would calibrate this per host
MAX_P95_SOLVE_S = 2.0


def run(*, smoke: bool = False, json_path: str = ""):
    print("== serve: registry + continuous batching under mixed traffic ==")
    with enable_x64():
        if smoke:
            L = lung2_like(scale=0.02, fat_levels=12, thin_run=24,
                           dtype=np.float64)
            traffic_kwargs = dict(num_patterns=3, num_tenants=4,
                                  num_events=120, n=192)
        else:
            L = lung2_like(scale=0.3, dtype=np.float64)
            traffic_kwargs = dict(num_patterns=4, num_tenants=8,
                                  num_events=600, n=512)
        emit("serve.rows", L.n)
        emit("serve.nnz", L.nnz)
        results: dict = {"rows": L.n, "nnz": L.nnz}
        rng = np.random.default_rng(0)
        b = rng.standard_normal(L.n)

        # -- warm-vs-cold economics on one lung2-class pattern ------------
        # The planned build is what a miss costs without the registry; the
        # refresh is what a pattern hit costs with it.
        strategy = "levelset"
        t0 = time.perf_counter()
        reg = SolverRegistry(strategy=strategy, background=False,
                             max_batch=8)
        entry = reg.get(L)
        reg.wait_idle()
        cold_total_s = time.perf_counter() - t0
        planned_s = entry.planned_build_seconds
        serial_s = entry.cold_build_seconds
        req_cold = entry.engine.submit(b)
        entry.engine.run()

        t0 = time.perf_counter()
        entry2 = reg.get(CSRMatrix(L.indptr, L.indices,
                                   refresh_values(L, seed=11), L.shape))
        warm_s = time.perf_counter() - t0
        assert entry2 is entry and reg.hits == 1
        warm_speedup = cold_total_s / warm_s
        emit("serve.cold.serial_build_s", f"{serial_s:.3e}", "s")
        emit("serve.cold.planned_build_s", f"{planned_s:.3e}", "s")
        emit("serve.cold.total_admission_s", f"{cold_total_s:.3e}", "s")
        emit("serve.warm.refresh_s", f"{warm_s:.3e}", "s")
        emit("serve.warm.speedup_vs_cold", round(warm_speedup, 1), "x")
        results["warm"] = dict(
            serial_build_s=serial_s, planned_build_s=planned_s,
            cold_admission_s=cold_total_s, refresh_s=warm_s,
            speedup=warm_speedup)

        # -- cold path answers before the background build lands ----------
        # The gate holds the planned build so "answered while cold" is a
        # pinned fact, not a race; releasing it then proves promotion and
        # value-identical answers on the same RHS.
        gate = threading.Event()
        reg2 = SolverRegistry(strategy=strategy, background=True,
                              build_gate=gate, max_batch=8)
        t0 = time.perf_counter()
        e2 = reg2.get(L)
        first_answer_s = None
        req = e2.engine.submit(b)
        e2.engine.run()
        first_answer_s = time.perf_counter() - t0
        cold_served = req.done and e2.state == "cold"
        gate.set()
        promoted = e2.wait_ready(timeout=600) and e2.state == "ready"
        req_warm = e2.engine.submit(b)
        e2.engine.run()
        answers_match = bool(np.allclose(req.x, req_warm.x,
                                         rtol=1e-10, atol=1e-10))
        emit("serve.cold.first_answer_s", f"{first_answer_s:.3e}", "s")
        emit("serve.cold.served_while_cold", cold_served)
        emit("serve.cold.promoted", promoted)
        emit("serve.cold.promoted_strategy", e2.engine.solver.strategy)
        emit("serve.cold.answers_match", answers_match)
        results["cold"] = dict(
            first_answer_s=first_answer_s, served_while_cold=cold_served,
            promoted=promoted, answers_match=answers_match)

        # -- mixed multi-tenant traffic under a byte budget ----------------
        probe = SpTRSV.build(
            serve_traffic(**{**traffic_kwargs, "num_tenants": 1,
                             "num_events": 0})[0][0],
            strategy=strategy)
        entry_bytes = probe.stats()["packed_bytes"] * 2  # fwd + bwd pair
        budget = int(entry_bytes * 2.5)  # holds ~2 of the patterns
        svc = SolveService(strategy=strategy, max_bytes=budget,
                           background=True, max_batch=16)
        patterns, events = serve_traffic(seed=7, **traffic_kwargs)
        peak = 0
        t0 = time.perf_counter()
        for ev in events:
            if ev["op"] == "register":
                svc.register(ev["tenant"], ev["matrix"])
            elif ev["op"] == "refresh":
                svc.refresh(ev["tenant"], ev["values"])
            else:
                svc.submit(ev["tenant"], ev["b"],
                           transpose=ev["transpose"])
            svc.step()
            peak = max(peak, svc.registry.resident_bytes())
        svc.run()
        svc.registry.wait_idle(timeout=600)
        peak = max(peak, svc.registry.resident_bytes())
        wall = time.perf_counter() - t0
        st = svc.stats()
        rs = st["registry"]
        throughput = st["completed"] / wall if wall else 0.0
        emit("serve.mixed.events", len(events))
        emit("serve.mixed.completed", st["completed"])
        emit("serve.mixed.failed", st["failed"])
        emit("serve.mixed.hits", rs["hits"])
        emit("serve.mixed.misses", rs["misses"])
        emit("serve.mixed.promotions", rs["promotions"])
        emit("serve.mixed.evictions", rs["evictions"])
        emit("serve.mixed.budget_bytes", budget)
        emit("serve.mixed.peak_resident_bytes", peak)
        emit("serve.mixed.throughput_rps", round(throughput, 1), "req/s")
        emit("serve.mixed.p50_solve_s",
             f"{st['solve_latency']['p50_s']:.3e}", "s")
        emit("serve.mixed.p95_solve_s",
             f"{st['solve_latency']['p95_s']:.3e}", "s")
        results["mixed"] = dict(
            events=len(events), completed=st["completed"],
            failed=st["failed"], hits=rs["hits"], misses=rs["misses"],
            promotions=rs["promotions"], evictions=rs["evictions"],
            budget_bytes=budget, peak_resident_bytes=peak,
            throughput_rps=throughput,
            p50_solve_s=st["solve_latency"]["p50_s"],
            p95_solve_s=st["solve_latency"]["p95_s"])

        if smoke:
            # PR-10 acceptance: warm (pattern-hit refresh) >= 10x a cold
            # admission, cold requests answered by the serial pair before
            # the background build completes (and promotion is value-
            # identical), and the registry never exceeds its byte budget
            # under mixed traffic that forces eviction.
            assert req_cold.done and req_cold.error is None
            assert warm_speedup >= MIN_WARM_SPEEDUP, (
                f"warm refresh only {warm_speedup:.1f}x faster than cold "
                f"admission (need >= {MIN_WARM_SPEEDUP}x)")
            assert cold_served, "cold request not answered while build held"
            assert promoted, "planned build never promoted"
            assert answers_match, "promoted pair changed the answers"
            assert st["failed"] == 0, st["per_tenant"]
            assert st["queue_depth"] == 0
            assert rs["evictions"] >= 1, (
                "traffic never exercised the byte budget — raise "
                "num_patterns or lower the budget")
            assert peak <= budget, (
                f"resident packed bytes peaked at {peak} > budget {budget}")
            assert st["solve_latency"]["p95_s"] <= MAX_P95_SOLVE_S, (
                f"p95 batch solve {st['solve_latency']['p95_s']:.3f}s > "
                f"SLO {MAX_P95_SOLVE_S}s")
            print(f"  smoke assertions passed (warm {warm_speedup:.0f}x >= "
                  f"{MIN_WARM_SPEEDUP}x, cold served while building, "
                  f"peak {peak} <= budget {budget} with "
                  f"{rs['evictions']} eviction(s))")

        if json_path:
            write_bench_json(json_path, "serve", results,
                             n=results["rows"], nnz=results["nnz"])
        return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small matrices + acceptance assertions (CI)")
    ap.add_argument("--json", default="", help="write results JSON here")
    ap.add_argument("--csv", default="")
    args = ap.parse_args()
    run(smoke=args.smoke, json_path=args.json)
    if args.csv:
        flush_csv(args.csv)
