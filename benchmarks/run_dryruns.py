"""Sweep driver: every (arch × shape × mesh) dry-run cell as an isolated
subprocess (compile crashes/memory never take down the sweep), bounded
parallelism, JSON results cached — re-running skips finished cells.

    PYTHONPATH=src python benchmarks/run_dryruns.py [--jobs 3] [--mesh both]
        [--only arch1,arch2] [--force]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from concurrent.futures import ThreadPoolExecutor, as_completed

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import ARCH_IDS, SHAPES, get_config, runs_cell  # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def set_out(path):
    global OUT
    OUT = path

# cheapest-first so failures surface early; heavy MoE/deep nets last
ORDER = ["xlstm-350m", "gemma3-1b", "recurrentgemma-2b", "paligemma-3b",
         "whisper-medium", "granite-3-8b", "gemma3-12b", "llama4-scout-17b-a16e",
         "qwen1.5-32b", "arctic-480b"]
SHAPE_ORDER = ["train_4k", "decode_32k", "prefill_32k", "long_500k"]


def cell_path(arch, shape, mesh):
    return os.path.join(OUT, f"{arch}__{shape}__{mesh}.json")


def run_one(arch, shape, mesh, timeout=7200):
    path = cell_path(arch, shape, mesh)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", mesh, "--out", OUT]
    t0 = time.time()
    try:
        r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                           timeout=timeout,
                           cwd=os.path.join(os.path.dirname(__file__), ".."))
        ok = r.returncode == 0
        if not ok and not os.path.exists(path):
            with open(path, "w") as f:
                json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                           "status": "crash", "stderr": r.stderr[-3000:]}, f)
    except subprocess.TimeoutExpired:
        ok = False
        with open(path, "w") as f:
            json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                       "status": "timeout", "timeout_s": timeout}, f)
    return arch, shape, mesh, ok, time.time() - t0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=3)
    ap.add_argument("--mesh", default="both", choices=["pod", "multipod", "both"])
    ap.add_argument("--only", default="")
    ap.add_argument("--shapes", default="")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--outdir", default="")
    args = ap.parse_args()

    if args.outdir:
        set_out(os.path.join(os.path.dirname(__file__), "results", args.outdir))
    os.makedirs(OUT, exist_ok=True)
    archs = [a for a in ORDER if a in ARCH_IDS]
    if args.only:
        archs = [a for a in archs if a in args.only.split(",")]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    shapes = args.shapes.split(",") if args.shapes else SHAPE_ORDER

    cells = []
    for mesh in meshes:
        for shape in shapes:
            for arch in archs:
                cfg = get_config(arch)
                if not runs_cell(cfg, shape):
                    # record the skip without spawning a process
                    p = cell_path(arch, shape, mesh)
                    if not os.path.exists(p):
                        from repro.configs import skip_reason
                        with open(p, "w") as f:
                            json.dump({"arch": arch, "shape": shape,
                                       "mesh": mesh, "status": "skipped",
                                       "reason": skip_reason(cfg, shape)}, f)
                    continue
                if not args.force and os.path.exists(cell_path(arch, shape, mesh)):
                    rec = json.load(open(cell_path(arch, shape, mesh)))
                    if rec.get("status") == "ok":
                        continue
                cells.append((arch, shape, mesh))

    print(f"[sweep] {len(cells)} cells to run, jobs={args.jobs}")
    n_ok = n_fail = 0
    with ThreadPoolExecutor(max_workers=args.jobs) as ex:
        futs = {ex.submit(run_one, *c): c for c in cells}
        for fut in as_completed(futs):
            arch, shape, mesh, ok, dt = fut.result()
            n_ok += ok
            n_fail += not ok
            print(f"[sweep] {'OK  ' if ok else 'FAIL'} {arch} x {shape} x {mesh}"
                  f" ({dt:.0f}s)  [{n_ok} ok / {n_fail} fail]", flush=True)
    print(f"[sweep] done: {n_ok} ok, {n_fail} fail")


if __name__ == "__main__":
    main()
