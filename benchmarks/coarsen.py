"""Schedule-coarsening benchmark: sync points, build time, per-solve time.

The paper removes barriers by rewriting equations; coarsening removes them
by *merging* adjacent levels under a cost model (arXiv:2503.05408's lever,
applied to our segment schedule).  On a lung2-class matrix the level-set
schedule has ~478 segments — 478 barrier-separated XLA program regions —
while the coarsened schedule packs thin runs into super-level slabs whose
intra-slab chains run back-to-back inside one segment.

Reported per configuration:

* ``segments``       barrier count of the executed schedule (sync points)
* ``build_s``        schedule build + executor trace + compile time
* ``solve_s``        median per-solve wall time
* ``max_err``        vs the row-serial oracle solve

``--smoke`` runs a scaled-down matrix and *asserts* the PR-3 acceptance
criteria: >= 4x fewer executed segments, oracle-match to fp tolerance, and
per-solve time within noise of the uncoarsened baseline — a CI guard
against schedule-size regressions the unit tests cannot see.

Usage::

    python -m benchmarks.coarsen             # full lung2-scale run
    python -m benchmarks.coarsen --smoke     # CI smoke w/ assertions
    python -m benchmarks.coarsen --smoke --json BENCH_coarsen.json
"""
from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.core import SpTRSV
from repro.core.coarsen import CoarsenConfig, coarsen_stats
from repro.sparse import lung2_like

try:  # runnable both as `python -m benchmarks.coarsen` and as a file
    from .common import emit, flush_csv, timeit, write_bench_json
except ImportError:  # pragma: no cover
    from common import emit, flush_csv, timeit, write_bench_json


def run(*, smoke: bool = False, json_path: str = ""):
    print("== coarsen: synchronization-aware level merging ==")
    if smoke:
        L = lung2_like(scale=0.05, fat_levels=8, thin_run=12, dtype=np.float32)
        iters, warmup = 10, 2  # sub-ms solves: medians need samples on CI
    else:
        L = lung2_like(scale=1.0, dtype=np.float32)
        iters, warmup = 5, 2
    emit("coarsen.rows", L.n)
    emit("coarsen.nnz", L.nnz)

    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.standard_normal(L.n).astype(np.float32))
    oracle = np.asarray(SpTRSV.build(L, strategy="serial").solve(b))

    results = {}
    for coarsen, tag in ((None, "base"), (True, "coarsen")):
        t0 = time.perf_counter()
        s = SpTRSV.build(L, strategy="levelset", coarsen=coarsen)
        s.solve(b).block_until_ready()  # include trace+compile in build_s
        build_s = time.perf_counter() - t0
        solve_s = timeit(s.solve, b, iters=iters, warmup=warmup)
        err = float(np.abs(np.asarray(s.solve(b)) - oracle).max())
        segs = s.schedule.num_segments
        emit(f"coarsen.{tag}.segments", segs)
        emit(f"coarsen.{tag}.build_s", round(build_s, 4), "s")
        emit(f"coarsen.{tag}.solve_s", f"{solve_s:.3e}", "s")
        emit(f"coarsen.{tag}.max_err", f"{err:.2e}")
        results[tag] = dict(segments=segs, build_s=build_s,
                            solve_s=solve_s, err=err, schedule=s.schedule)

    st = coarsen_stats(results["base"]["schedule"],
                       results["coarsen"]["schedule"])
    print("  " + st.summary())
    ratio = results["base"]["segments"] / max(results["coarsen"]["segments"], 1)
    speedup = results["base"]["solve_s"] / results["coarsen"]["solve_s"]
    emit("coarsen.segment_reduction", round(ratio, 2), "x")
    emit("coarsen.solve_speedup", round(speedup, 3), "x")
    emit("coarsen.build_speedup",
         round(results["base"]["build_s"] / results["coarsen"]["build_s"], 3),
         "x")

    # auto planner on the same matrix — must build and match the oracle
    s_auto = SpTRSV.build(L, strategy="auto")
    err_auto = float(np.abs(np.asarray(s_auto.solve(b)) - oracle).max())
    emit("coarsen.auto.strategy", s_auto.strategy,
         coarsen=s_auto.plan.coarsen)
    emit("coarsen.auto.max_err", f"{err_auto:.2e}")

    if smoke:
        # PR-3 acceptance: >= 4x fewer sync points, fp-tolerance solution,
        # per-solve time no worse than the uncoarsened baseline.  The
        # deterministic asserts guard the real regressions; the timing one
        # gets generous slack because a sub-millisecond median on a shared
        # CI runner is noisy — it exists to catch gross blowups (e.g. a fat
        # wavefront slipping into a chain is a ~10x padded-work change).
        assert ratio >= 4.0, f"segment reduction {ratio:.1f}x < 4x"
        assert results["coarsen"]["err"] < 1e-5, results["coarsen"]["err"]
        assert err_auto < 1e-5, err_auto
        assert results["coarsen"]["solve_s"] <= 2.5 * results["base"]["solve_s"], (
            f"coarsened solve {results['coarsen']['solve_s']:.3e}s vs "
            f"baseline {results['base']['solve_s']:.3e}s")
        print("  smoke assertions passed "
              f"({ratio:.1f}x fewer segments, err {results['coarsen']['err']:.1e})")

    if json_path:
        results["segment_reduction"] = ratio
        results["solve_speedup"] = speedup
        results["auto"] = dict(strategy=s_auto.strategy,
                               coarsen=s_auto.plan.coarsen, err=err_auto)
        write_bench_json(json_path, "coarsen", results, n=L.n, nnz=L.nnz)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small matrix + acceptance assertions (CI)")
    ap.add_argument("--json", default="", help="write shared-schema JSON here")
    ap.add_argument("--csv", default="")
    args = ap.parse_args()
    run(smoke=args.smoke, json_path=args.json)
    if args.csv:
        flush_csv(args.csv)
