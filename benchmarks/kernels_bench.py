"""Pallas kernel micro-benchmarks.

Wall-clock on this CPU container measures the *interpret-mode* kernels —
meaningless as TPU time — so alongside a CPU sanity timing we report the
structural metrics the TPU roofline cares about: padded FLOPs (lane
occupancy), VMEM working set per block, HBM bytes per solve.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import RewriteConfig, SpTRSV
from repro.core.codegen import build_schedule
from repro.sparse import lung2_like

from .common import emit, timeit


def run(full_scale: bool = False):
    print("== kernels_bench: Pallas kernel structure + sanity timing ==")
    L = lung2_like(scale=0.05, dtype=np.float32)
    b = jnp.asarray(np.random.default_rng(0).normal(size=L.n).astype(np.float32))
    sched = build_schedule(L)
    n = L.n

    emit("kern.matrix_rows", n)
    emit("kern.levels", sched.num_levels)
    pf = sched.padded_flops()
    emit("kern.padded_flops", pf)
    emit("kern.useful_flops", L.solve_flops())
    emit("kern.lane_occupancy", f"{100*L.solve_flops()/max(pf,1):.1f}", "%",
         note="ELL padding waste = idle lanes")
    # VMEM working set of the fused kernel: x (n_pad f32) + largest slab block
    x_bytes = 4 * (n + 1)
    slab_bytes = max(4 * (2 * s.K + 2) * min(s.R, 512) for s in sched.slabs)
    emit("kern.fused_vmem_x_bytes", x_bytes, "B", budget="~16MiB VMEM")
    emit("kern.level_block_bytes", slab_bytes, "B")
    emit("kern.hbm_bytes_per_solve", 4 * (2 * L.nnz + 2 * n), "B",
         note="vals+cols+b+x streams")

    for strat in ("levelset", "pallas_level", "pallas_fused"):
        s = SpTRSV.build(L, strategy=strat, interpret=True)
        t = timeit(s.solve, b, iters=3, warmup=1)
        emit(f"kern.{strat}.cpu_ms", f"{t*1e3:.2f}", "ms",
             note="interpret-mode sanity" if "pallas" in strat else "XLA CPU")
    return True


if __name__ == "__main__":
    run()
