"""Permuted-space packed execution + value-only refresh benchmark.

Measures the two claims of the permuted-execution PR on a lung2-class
matrix:

* ``refresh`` — re-solving the same sparsity pattern with new values
  (every numeric re-factorization of an iterative PCG/IC workload) reuses
  the cached symbolic schedule and the compiled executable:
  ``SpTRSV.refresh`` is one O(nnz) value re-pack, asserted **>= 10x** faster
  than a cold ``SpTRSV.build`` (which pays analysis + packing + trace +
  compile).
* ``permuted vs scatter`` — per-solve wall time of the permuted-space
  packed executor against the legacy per-segment scatter executor for each
  strategy; permuted must be no slower, and is typically faster on the
  levelset paths (contiguous b̂/x̂ slices instead of row-id gathers and
  scatters).

Reported per configuration (also emitted as JSON with ``--json`` for the
CI perf-trajectory artifact):

* ``build_s``      cold build incl. executor trace + compile + first solve
* ``refresh_s``    value-only refresh (cached schedule, no re-trace)
* ``solve_s``      median per-solve wall time (permuted / scatter)
* packed-buffer bytes and padding waste from ``SpTRSV.stats()``

Usage::

    python -m benchmarks.refresh              # full lung2-scale run
    python -m benchmarks.refresh --smoke      # CI smoke w/ assertions
    python -m benchmarks.refresh --smoke --json BENCH_refresh.json
"""
from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.core import SpTRSV
from repro.core.csr import CSRMatrix
from repro.sparse import lung2_like

try:  # runnable both as `python -m benchmarks.refresh` and as a file
    from .common import emit, flush_csv, timeit, write_bench_json
except ImportError:  # pragma: no cover
    from common import emit, flush_csv, timeit, write_bench_json


def _new_values(L: CSRMatrix, seed: int) -> np.ndarray:
    """Regenerated values on the same pattern, kept diagonally dominant."""
    rng = np.random.default_rng(seed)
    data = (L.data + 0.05 * rng.standard_normal(L.nnz)).astype(L.dtype)
    data[L.indptr[1:] - 1] += 2.0  # lower-triangular: diagonal last per row
    return data


def run(*, smoke: bool = False, json_path: str = ""):
    print("== refresh: permuted-space packed execution + value-only refresh ==")
    if smoke:
        L = lung2_like(scale=0.05, fat_levels=8, thin_run=12, dtype=np.float32)
        iters, warmup = 20, 3
        strategies = ("levelset", "levelset_unroll", "serial")
    else:
        # full lung2 scale; serial (minutes of scan) and pallas interpret
        # mode are left to --smoke — this run measures the two claims where
        # they matter, on the generated level-set executors
        L = lung2_like(scale=1.0, dtype=np.float32)
        iters, warmup = 5, 2
        strategies = ("levelset", "levelset_unroll")
    emit("refresh.rows", L.n)
    emit("refresh.nnz", L.nnz)

    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.standard_normal(L.n).astype(np.float32))
    oracle = np.asarray(SpTRSV.build(L, strategy="serial").solve(b))
    new_data = _new_values(L, seed=1)
    results: dict = {"n": L.n, "nnz": L.nnz, "strategies": {}}

    for strategy in strategies:
        coarsen = None if strategy == "serial" else True
        row: dict = {}
        for layout in ("permuted", "scatter"):
            t0 = time.perf_counter()
            s = SpTRSV.build(L, strategy=strategy, coarsen=coarsen,
                             layout=layout)
            s.solve(b).block_until_ready()  # trace + compile included
            build_s = time.perf_counter() - t0
            solve_s = timeit(s.solve, b, iters=iters, warmup=warmup)
            err = float(np.abs(np.asarray(s.solve(b)) - oracle).max())
            emit(f"refresh.{strategy}.{layout}.build_s", round(build_s, 4), "s")
            emit(f"refresh.{strategy}.{layout}.solve_s", f"{solve_s:.3e}", "s")
            emit(f"refresh.{strategy}.{layout}.max_err", f"{err:.2e}")
            row[layout] = dict(build_s=build_s, solve_s=solve_s, err=err)
            if layout == "permuted":
                st = s.stats()
                emit(f"refresh.{strategy}.packed_value_bytes",
                     st["packed_value_bytes"], "B")
                emit(f"refresh.{strategy}.padded_value_bytes",
                     st["padded_value_bytes"], "B")
                row["stats"] = {k: st[k] for k in (
                    "packed_value_bytes", "packed_index_bytes",
                    "padded_value_bytes", "permutation_applied", "segments")}
                # value-only refresh: cached schedule, no re-trace/compile
                t0 = time.perf_counter()
                s.refresh(new_data)
                s.solve(b).block_until_ready()  # must hit the jit cache
                refresh_s = time.perf_counter() - t0
                emit(f"refresh.{strategy}.refresh_s",
                     round(refresh_s, 4), "s")
                row["refresh_s"] = refresh_s
                # refreshed solver must match a cold build on the new values
                fresh = SpTRSV.build(
                    CSRMatrix(L.indptr, L.indices, new_data, L.shape),
                    strategy=strategy, coarsen=coarsen)
                rerr = float(np.abs(np.asarray(s.solve(b))
                                    - np.asarray(fresh.solve(b))).max())
                emit(f"refresh.{strategy}.refresh_err", f"{rerr:.2e}")
                row["refresh_err"] = rerr
        speed = row["scatter"]["solve_s"] / row["permuted"]["solve_s"]
        ratio = row["permuted"]["build_s"] / row["refresh_s"]
        emit(f"refresh.{strategy}.permuted_speedup", round(speed, 3), "x")
        emit(f"refresh.{strategy}.refresh_speedup", round(ratio, 1), "x",
             note="cold build / refresh")
        results["strategies"][strategy] = row

    if smoke:
        # Acceptance: refresh >= 10x faster than a cold build; permuted
        # per-solve time no slower than the scatter path (generous slack:
        # sub-millisecond medians on shared CI runners are noisy — the
        # assert exists to catch structural regressions, e.g. a per-segment
        # re-permute sneaking back in, not 10% jitter).
        for strategy, row in results["strategies"].items():
            ratio = row["permuted"]["build_s"] / row["refresh_s"]
            assert ratio >= 10.0, (
                f"{strategy}: refresh only {ratio:.1f}x faster than cold "
                f"build ({row['refresh_s']:.3f}s vs "
                f"{row['permuted']['build_s']:.3f}s)")
            assert row["refresh_err"] < 1e-5, (strategy, row["refresh_err"])
            assert row["permuted"]["err"] < 1e-5, (strategy, row["permuted"])
            # serial has no permuted space (same scan, values as runtime
            # buffers) — its guard only catches gross blowups; sub-100us
            # medians on shared runners jitter +-50%
            slack = 2.0 if strategy == "serial" else 1.15
            assert row["permuted"]["solve_s"] <= slack * row["scatter"]["solve_s"], (
                f"{strategy}: permuted solve "
                f"{row['permuted']['solve_s']:.3e}s slower than scatter "
                f"{row['scatter']['solve_s']:.3e}s")
        print("  smoke assertions passed (refresh >= 10x cold build, "
              "permuted <= scatter per-solve)")

    if json_path:
        write_bench_json(json_path, "refresh", results, n=L.n, nnz=L.nnz)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small matrix + acceptance assertions (CI)")
    ap.add_argument("--json", default="", help="write results JSON here")
    ap.add_argument("--csv", default="")
    args = ap.parse_args()
    run(smoke=args.smoke, json_path=args.json)
    if args.csv:
        flush_csv(args.csv)
