"""Blocked (supernodal) SpTRSV benchmark: dense-band amalgamation vs the
coarsened level-set executor.

The blocked executor's bet is that on factors with dense-ish diagonal blocks
(banded / reordered matrices — the paper's ref [22] scenario) the schedule
collapses from one segment per wavefront to one segment per *super-level*,
and each segment's work turns from padded gathers into contiguous batched
small-TRSM applies.  On a dense band with ``max_block=128`` supernodes the
segment count drops ~4x below even the coarsened level-set schedule; the
wall-clock win materializes on multi-RHS solves, where the diagonal-block
apply is one contiguous batched GEMM per super-level while the level-set
chain pays a widened gather per serial row step (~6x at batch=8 on the CPU
interpret backend, far more on MXU hardware where the calibration prices a
dense flop at 1/20th of a gathered one).

Reported per configuration:

* ``segments``        barrier count of the executed schedule
* ``mean_block_size`` supernode amalgamation quality
* ``build_s``         schedule build + trace + compile time
* ``solve_s``         median per-solve wall time
* ``max_err``         vs the row-serial oracle solve

``--smoke`` runs a scaled-down dense band and *asserts* the ISSUE-8
acceptance criteria: blocked >= 1.3x over the coarsened level-set executor
on the banded factor's batched solve, oracle-match to fp tolerance, and —
on a lung2-class matrix, where amalgamation finds nothing — the auto
planner's pick is byte-identical to a build with supernodes disabled
(adding the blocked candidate must never regress existing planner
decisions).

Usage::

    python -m benchmarks.blocked             # full-scale run
    python -m benchmarks.blocked --smoke     # CI smoke w/ assertions
    python -m benchmarks.blocked --smoke --json BENCH_blocked.json
"""
from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.core import SpTRSV
from repro.core.levels import SupernodeConfig
from repro.sparse import lung2_like
from repro.sparse.generate import banded_lower

try:  # runnable both as `python -m benchmarks.blocked` and as a file
    from .common import emit, flush_csv, timeit, write_bench_json
except ImportError:  # pragma: no cover
    from common import emit, flush_csv, timeit, write_bench_json


def _build_and_time(L, b, oracle, tag, *, iters, warmup, b_batch=None, **kw):
    t0 = time.perf_counter()
    s = SpTRSV.build(L, **kw)
    s.solve(b).block_until_ready()  # include trace+compile in build_s
    build_s = time.perf_counter() - t0
    solve_s = timeit(s.solve, b, iters=iters, warmup=warmup)
    err = float(np.abs(np.asarray(s.solve(b)) - oracle).max())
    st = s.stats()
    emit(f"blocked.{tag}.segments", st["segments"])
    emit(f"blocked.{tag}.build_s", round(build_s, 4), "s")
    emit(f"blocked.{tag}.solve_s", f"{solve_s:.3e}", "s")
    emit(f"blocked.{tag}.max_err", f"{err:.2e}")
    res = dict(segments=st["segments"], build_s=build_s,
               solve_s=solve_s, err=err)
    if b_batch is not None:
        res["batch_solve_s"] = timeit(s.solve, b_batch,
                                      iters=iters, warmup=warmup)
        emit(f"blocked.{tag}.batch_solve_s", f"{res['batch_solve_s']:.3e}",
             "s", batch=b_batch.shape[1])
    return s, res


def run(*, smoke: bool = False, json_path: str = ""):
    print("== blocked: supernodal solves vs coarsened level sets ==")
    if smoke:
        n, bw, iters, warmup = 4096, 24, 10, 3
    else:
        n, bw, iters, warmup = 8192, 24, 10, 3
    L = banded_lower(n, bandwidth=bw, fill=1.0, seed=0, dtype=np.float32)
    emit("blocked.rows", L.n)
    emit("blocked.nnz", L.nnz)

    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.standard_normal(L.n).astype(np.float32))
    b8 = jnp.asarray(rng.standard_normal((L.n, 8)).astype(np.float32))
    oracle = np.asarray(SpTRSV.build(L, strategy="serial").solve(b))

    results = {}
    _, results["levelset"] = _build_and_time(
        L, b, oracle, "levelset", iters=iters, warmup=warmup, b_batch=b8,
        strategy="levelset", coarsen=True)
    s_blk, results["blocked"] = _build_and_time(
        L, b, oracle, "blocked", iters=iters, warmup=warmup, b_batch=b8,
        strategy="blocked", layout="permuted",
        supernodes=SupernodeConfig(relax=0.25, max_block=128))
    st = s_blk.stats()
    emit("blocked.mean_block_size", round(st["mean_block_size"], 2))
    emit("blocked.dense_block_fraction", round(st["dense_block_fraction"], 4))
    results["blocked"].update(mean_block_size=st["mean_block_size"],
                              dense_block_fraction=st["dense_block_fraction"])

    speedup = results["levelset"]["solve_s"] / results["blocked"]["solve_s"]
    batch_speedup = (results["levelset"]["batch_solve_s"]
                     / results["blocked"]["batch_solve_s"])
    seg_ratio = results["levelset"]["segments"] / max(
        results["blocked"]["segments"], 1)
    emit("blocked.solve_speedup", round(speedup, 3), "x")
    emit("blocked.batch_solve_speedup", round(batch_speedup, 3), "x")
    emit("blocked.segment_reduction", round(seg_ratio, 2), "x")
    results["solve_speedup"] = speedup
    results["batch_solve_speedup"] = batch_speedup
    results["segment_reduction"] = seg_ratio

    # --- lung2-class guard: amalgamation finds nothing there, the planner
    # gate must keep the blocked candidate out, and auto's pick must be
    # identical to a build with supernodes disabled.
    Ll = lung2_like(scale=0.05, fat_levels=8, thin_run=12, dtype=np.float32)
    bl = jnp.asarray(rng.standard_normal(Ll.n).astype(np.float32))
    oracle_l = np.asarray(SpTRSV.build(Ll, strategy="serial").solve(bl))
    s_auto, auto_res = _build_and_time(
        Ll, bl, oracle_l, "lung2_auto", iters=iters, warmup=warmup,
        strategy="auto")
    s_base, base_res = _build_and_time(
        Ll, bl, oracle_l, "lung2_prior", iters=iters, warmup=warmup,
        strategy="auto", supernodes=False)
    emit("blocked.lung2.auto_strategy", s_auto.strategy)
    emit("blocked.lung2.mean_block_size",
         round(s_auto.stats()["mean_block_size"], 2))
    results["lung2"] = dict(auto=auto_res, prior=base_res,
                            strategy=s_auto.strategy,
                            strategy_unchanged=s_auto.strategy == s_base.strategy)

    if smoke:
        # ISSUE-8 acceptance.  The deterministic asserts (segment reduction,
        # planner identity, fp error) guard the real regressions; the timing
        # asserts get slack only in the noise-prone direction — blocked must
        # still clear 1.3x on the band, and auto on lung2 may not be grossly
        # slower than the pre-blocked planner's pick.
        assert batch_speedup >= 1.3, (
            f"blocked batched speedup {batch_speedup:.2f}x < 1.3x")
        assert seg_ratio >= 2.0, f"segment reduction {seg_ratio:.1f}x < 2x"
        # single-RHS must stay within noise of the level-set executor (the
        # batched solve is where the GEMM advantage lives)
        assert speedup >= 0.4, f"single-RHS blocked {speedup:.2f}x"
        assert results["blocked"]["err"] < 1e-4, results["blocked"]["err"]
        assert s_auto.strategy == s_base.strategy, (
            f"blocked candidate changed the lung2 plan: "
            f"{s_auto.strategy} != {s_base.strategy}")
        assert s_auto.plan.reason == s_base.plan.reason
        assert auto_res["solve_s"] <= 2.5 * base_res["solve_s"], (
            f"auto with supernode gate {auto_res['solve_s']:.3e}s vs prior "
            f"pick {base_res['solve_s']:.3e}s")
        print(f"  smoke assertions passed ({batch_speedup:.2f}x over "
              f"coarsened levelset at batch=8, lung2 plan unchanged: "
              f"{s_auto.strategy})")

    if json_path:
        write_bench_json(json_path, "blocked", results, n=L.n, nnz=L.nnz)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small matrix + acceptance assertions (CI)")
    ap.add_argument("--json", default="", help="write shared-schema JSON here")
    ap.add_argument("--csv", default="")
    args = ap.parse_args()
    run(smoke=args.smoke, json_path=args.json)
    if args.csv:
        flush_csv(args.csv)
