"""Multi-RHS SpTRSV throughput sweep: per-solve wall time vs batch width.

The paper amortizes analysis cost over many solves of one L; batching
amortizes *execution* overhead the same way — per-level launch cost and the
underfilled lane dimension of thin levels are paid once per level per batch,
not once per level per RHS.  On a lung2-class matrix (hundreds of levels,
most of them 2 rows wide) this is the difference between a latency-bound and
a throughput-bound solve.

Sweeps ``m ∈ {1, 8, 64, 256}`` over the pure-JAX strategies (and the Pallas
kernels in interpret mode when ``--pallas`` is given — interpret is far too
slow for wall-clock claims, so it is excluded from the default sweep) and
reports seconds per *solve* (batch time / m), which should fall — or at
worst stay flat — as m grows.

Usage::

    python -m benchmarks.batch_solve             # full sweep
    python -m benchmarks.batch_solve --dry-run   # tiny smoke (CI)
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp
import numpy as np

from repro.core import RewriteConfig, SpTRSV
from repro.sparse import lung2_like

try:  # runnable both as `python -m benchmarks.batch_solve` and as a file
    from .common import emit, flush_csv, timeit, write_bench_json
except ImportError:  # pragma: no cover
    from common import emit, flush_csv, timeit, write_bench_json


def run(*, dry_run: bool = False, pallas: bool = False,
        json_path: str = ""):
    print("== batch_solve: per-solve wall time vs batch width ==")
    if dry_run:
        L = lung2_like(scale=0.02, fat_levels=4, thin_run=6, dtype=np.float32)
        widths = (1, 8)
        iters, warmup = 2, 1
    else:
        # lung2_like(478 levels)-class input: scale=1.0 gives ~110k rows,
        # ~480 levels, 94% of them 2 rows wide.
        L = lung2_like(scale=1.0, dtype=np.float32)
        widths = (1, 8, 64, 256)
        iters, warmup = 5, 2
    emit("batch.rows", L.n)
    emit("batch.nnz", L.nnz)

    strategies = ["levelset", "levelset_unroll"]
    if pallas:
        strategies += ["pallas_level", "pallas_fused"]

    rng = np.random.default_rng(0)
    results = {}
    for strategy in strategies:
        for rewrite, tag in ((None, "base"),
                             (RewriteConfig(thin_threshold=2), "rewrite")):
            s = SpTRSV.build(L, strategy=strategy, rewrite=rewrite)
            base_per_solve = None
            for m in widths:
                B = jnp.asarray(
                    rng.normal(size=(L.n, m)).astype(np.float32))
                arg = B[:, 0] if m == 1 else B
                t = timeit(s.solve, arg, iters=iters, warmup=warmup)
                per_solve = t / m
                if base_per_solve is None:
                    base_per_solve = per_solve
                speedup = base_per_solve / per_solve
                emit(
                    f"batch.{strategy}.{tag}.m{m}.per_solve_ms",
                    f"{per_solve * 1e3:.3f}", "ms",
                    batch=m, speedup_vs_m1=f"{speedup:.2f}x",
                )
                results[(strategy, tag, m)] = per_solve
    # Headline: did per-solve time improve (or at least not regress) with m?
    for strategy in strategies:
        for tag in ("base", "rewrite"):
            series = [results[(strategy, tag, m)] for m in widths]
            trend = "improving" if series[-1] <= series[0] else "REGRESSING"
            emit(f"batch.{strategy}.{tag}.trend", trend,
                 m1_ms=f"{series[0]*1e3:.3f}",
                 mmax_ms=f"{series[-1]*1e3:.3f}")
    if json_path:
        flat = {f"{strategy}.{tag}.m{m}": {"per_solve_s": t}
                for (strategy, tag, m), t in results.items()}
        write_bench_json(json_path, "batch", flat, n=L.n, nnz=L.nnz)
    return results


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny matrix, 2 widths, 2 iters (CI smoke)")
    ap.add_argument("--pallas", action="store_true",
                    help="include Pallas kernels (interpret mode; slow)")
    ap.add_argument("--json", default="", help="write shared-schema JSON here")
    ap.add_argument("--csv", default=None, help="write results CSV here")
    args = ap.parse_args(argv)
    run(dry_run=args.dry_run, pallas=args.pallas, json_path=args.json)
    if args.csv:
        flush_csv(args.csv)


if __name__ == "__main__":
    main()
