"""Paper §V Experiment 2: end-to-end solve with equation rewriting applied.

Paper (lung2, serial run of the rewritten generated code): 2.06 ms vs
1.98 ms unrewritten — rewriting pays +10% FLOPs, the win arrives with
parallel hardware (fewer, fatter levels).  On TPU/XLA the "parallel
hardware" is the vector unit: we report solve time with/without rewriting
AND the structural metrics that determine the parallel win (levels =
sequential segments; padded-FLOP waste = idle lanes).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import RewriteConfig, SpTRSV
from repro.sparse import lung2_like

from .common import emit, timeit


def run(full_scale: bool = True):
    print("== exp2_rewrite: rewritten solver end-to-end ==")
    L = lung2_like(scale=1.0 if full_scale else 0.1, dtype=np.float32)
    b = jnp.asarray(np.random.default_rng(0).normal(size=L.n).astype(np.float32))

    base = SpTRSV.build(L, strategy="levelset")
    rw = SpTRSV.build(L, strategy="levelset",
                      rewrite=RewriteConfig(thin_threshold=2))
    # §Perf solver iteration 1: rewritten rows carry fill-in; one max-width
    # slab per level pays their K for every native row.  nnz-bucketed slabs
    # (the paper's "multiple functions per thick level") cap the padding.
    rw_bucket = SpTRSV.build(L, strategy="levelset",
                             rewrite=RewriteConfig(thin_threshold=2),
                             bucket_pad_ratio=2.0)

    t_base = timeit(base.solve, b, iters=5, warmup=2)
    t_rw = timeit(rw.solve, b, iters=5, warmup=2)
    t_rwb = timeit(rw_bucket.solve, b, iters=5, warmup=2)
    st = rw.stats

    emit("exp2.levelset_ms", f"{t_base*1e3:.2f}", "ms")
    emit("exp2.rewritten_ms", f"{t_rw*1e3:.2f}", "ms")
    emit("exp2.rewritten_bucketed_ms", f"{t_rwb*1e3:.2f}", "ms",
         note="beyond-paper: nnz-bucketed slabs")
    emit("exp2.padded_flops_plain", rw.schedule.padded_flops())
    emit("exp2.padded_flops_bucketed", rw_bucket.schedule.padded_flops())
    emit("exp2.slabs_plain", rw.schedule.num_levels)
    emit("exp2.slabs_bucketed", rw_bucket.schedule.num_levels)
    emit("exp2.speedup", f"{t_base/t_rw:.2f}", "x")
    emit("exp2.levels", f"{st.levels_before}->{st.levels_after}")
    emit("exp2.barriers_removed", f"{100*st.level_reduction:.1f}", "%")
    emit("exp2.flop_increase", f"{100*st.flop_increase:.1f}", "%")
    emit("exp2.paper_serial_rewritten_ms", 2.06, "ms", role="paper lung2")

    x0 = np.asarray(base.solve(b))
    x1 = np.asarray(rw.solve(b))
    x2 = np.asarray(rw_bucket.solve(b))
    np.testing.assert_allclose(x0, x1, rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(x0, x2, rtol=2e-3, atol=2e-4)
    print("  [check] rewritten (+bucketed) solutions match unrewritten")
    return {"base": t_base, "rewritten": t_rw, "bucketed": t_rwb, "stats": st}


if __name__ == "__main__":
    run()
