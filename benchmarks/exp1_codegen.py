"""Paper §V Experiment 1: specialized-codegen solver vs handwritten baseline
(serial execution, no rewriting).

Paper (lung2, dual-socket Westmere, clang): generated 1.98 ms vs handwritten
level-set 1.14 ms (the prototype generator loses ~1.7x from over-long code /
no merging).  Here both solvers are XLA-compiled; the "generated" one is the
matrix-specialized level-set executor (structure baked in as constants), the
"handwritten" baseline is the generic row-serial scan (Algorithm 1).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import RewriteConfig, SpTRSV
from repro.sparse import lung2_like

from .common import emit, timeit


def run(full_scale: bool = True):
    print("== exp1_codegen: specialized executor vs serial baseline ==")
    L = lung2_like(scale=1.0 if full_scale else 0.1, dtype=np.float32)
    b = jnp.asarray(np.random.default_rng(0).normal(size=L.n).astype(np.float32))

    serial = SpTRSV.build(L, strategy="serial")          # Algorithm 1
    levelset = SpTRSV.build(L, strategy="levelset")      # generated, no rewrite
    unrolled = SpTRSV.build(L, strategy="levelset_unroll", unroll_threshold=4)

    t_serial = timeit(serial.solve, b, iters=5, warmup=2)
    t_level = timeit(levelset.solve, b, iters=5, warmup=2)
    t_unroll = timeit(unrolled.solve, b, iters=5, warmup=2)

    emit("exp1.rows", L.n)
    emit("exp1.serial_ms", f"{t_serial*1e3:.2f}", "ms", role="handwritten Algorithm-1")
    emit("exp1.levelset_ms", f"{t_level*1e3:.2f}", "ms", role="generated per-level")
    emit("exp1.levelset_unroll_ms", f"{t_unroll*1e3:.2f}", "ms",
         role="generated + tiny-level constant unroll")
    emit("exp1.paper_generated_ms", 1.98, "ms", role="paper lung2")
    emit("exp1.paper_handwritten_ms", 1.14, "ms", role="paper lung2")
    return {"serial": t_serial, "levelset": t_level, "unroll": t_unroll}


if __name__ == "__main__":
    run()
