"""IC(0)/SpTRSV preconditioner: shared-analysis vs reverse-permute baseline.

The preconditioner apply is two triangular sweeps — forward ``L y = r`` and
backward ``Lᵀ z = y``.  The legacy construction materialized the backward
sweep as a *lower* solve on the reverse-permuted transpose: an extra
``from_coo`` transpose, another ``from_coo`` permutation, and a second full
``SpTRSV.build`` (level analysis, rewrite, packing) that knows nothing about
the first.  The shared-analysis construction (``SpTRSV.build_pair``) derives
the backward level sets from the forward DAG arrays and packs backward slabs
from an O(nnz) CSC view — one symbolic analysis for both sweeps.

Reported: build time (legacy vs shared), per-apply time, and PCG iteration
counts with each preconditioner (must be identical — the two constructions
compute the same operator).

Usage::

    python -m benchmarks.preconditioner             # full run
    python -m benchmarks.preconditioner --dry-run   # tiny smoke (CI)
"""
from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.core import RewriteConfig, SpTRSV
from repro.core.csr import from_coo
from repro.core.pcg import make_ic_preconditioner, pcg
from repro.sparse import ic0_factor, lung2_like, poisson2d

try:  # runnable both as `python -m benchmarks.preconditioner` and as a file
    from .common import emit, flush_csv, timeit
except ImportError:  # pragma: no cover
    from common import emit, flush_csv, timeit


def legacy_make_ic_preconditioner(L, *, strategy="levelset",
                                  rewrite=RewriteConfig(thin_threshold=2)):
    """The pre-transpose-support construction, kept verbatim as the
    baseline: transpose via from_coo, reverse-permute to lower-triangular,
    and a second independent SpTRSV.build for the backward sweep."""
    n = L.n
    rows = np.repeat(np.arange(n), L.row_nnz())
    Lt = from_coo(L.indices, rows, L.data, (n, n))
    rows_t = np.repeat(np.arange(n), Lt.row_nnz())
    Lt_rev = from_coo(n - 1 - rows_t, n - 1 - Lt.indices, Lt.data, (n, n))

    fwd = SpTRSV.build(L, strategy=strategy, rewrite=rewrite)
    bwd = SpTRSV.build(Lt_rev, strategy=strategy, rewrite=rewrite)

    def apply(r):
        y = fwd.solve(r)
        return bwd.solve(y[::-1])[::-1]

    return apply


def _time_build(fn, iters: int) -> float:
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def run(*, dry_run: bool = False):
    print("== preconditioner: shared-analysis vs reverse-permute baseline ==")
    # Build/apply comparison on a lung2-class factor — the paper's workload:
    # hundreds of levels, most of them thin, where per-row DAG traversal
    # dominates the analysis.  PCG iteration check on a poisson IC(0) system.
    if dry_run:
        L = lung2_like(scale=0.02, fat_levels=4, thin_run=6, dtype=np.float32)
        A = poisson2d(12, 12, dtype=np.float32)
        build_iters, tol, maxiter = 2, 1e-5, 200
    else:
        L = lung2_like(scale=0.25, dtype=np.float32)
        A = poisson2d(96, 96, dtype=np.float32)
        build_iters, tol, maxiter = 5, 1e-6, 1500
    emit("precond.rows", L.n)
    emit("precond.nnz", L.nnz)
    rewrite = RewriteConfig(thin_threshold=2)

    t_legacy = _time_build(
        lambda: legacy_make_ic_preconditioner(L, rewrite=rewrite), build_iters)
    t_shared = _time_build(
        lambda: make_ic_preconditioner(L, rewrite=rewrite), build_iters)
    emit("precond.build.legacy_ms", f"{t_legacy * 1e3:.2f}", "ms")
    emit("precond.build.shared_ms", f"{t_shared * 1e3:.2f}", "ms",
         speedup=f"{t_legacy / t_shared:.2f}x")

    M_legacy = legacy_make_ic_preconditioner(L, rewrite=rewrite)
    M_shared = make_ic_preconditioner(L, rewrite=rewrite)

    rng = np.random.default_rng(0)
    r = jnp.asarray(rng.normal(size=L.n).astype(np.float32))
    z_legacy = np.asarray(M_legacy(r))
    z_shared = np.asarray(M_shared(r))
    err = float(np.max(np.abs(z_legacy - z_shared))
                / max(np.max(np.abs(z_legacy)), 1e-30))
    emit("precond.apply.max_rel_diff", f"{err:.2e}")
    assert err < 1e-4, "shared-analysis apply diverged from the baseline"

    t_apply_legacy = timeit(M_legacy, r, iters=5, warmup=2)
    t_apply_shared = timeit(M_shared, r, iters=5, warmup=2)
    emit("precond.apply.legacy_ms", f"{t_apply_legacy * 1e3:.3f}", "ms")
    emit("precond.apply.shared_ms", f"{t_apply_shared * 1e3:.3f}", "ms",
         speedup=f"{t_apply_legacy / t_apply_shared:.2f}x")

    Lic = ic0_factor(A)
    b = jnp.asarray(rng.normal(size=A.n).astype(np.float32))
    res_legacy = pcg(A, b, legacy_make_ic_preconditioner(Lic, rewrite=rewrite),
                     tol=tol, maxiter=maxiter)
    res_shared = pcg(A, b, make_ic_preconditioner(Lic, rewrite=rewrite),
                     tol=tol, maxiter=maxiter)
    emit("precond.pcg.iters.legacy", res_legacy.iters)
    emit("precond.pcg.iters.shared", res_shared.iters)
    # The two constructions are the same operator up to f32 rounding (the
    # eliminations run over different representations), so a residual sitting
    # exactly at the tolerance boundary may converge one iteration apart —
    # allow that ulp-level wiggle, fail on anything larger.
    iter_slack = max(1, res_legacy.iters // 20)
    assert abs(res_shared.iters - res_legacy.iters) <= iter_slack, (
        "shared-analysis preconditioner changed PCG iteration count: "
        f"{res_shared.iters} vs {res_legacy.iters}")

    if t_shared >= t_legacy:
        print("  !! build-time regression: shared-analysis slower than baseline")
    print(f"  build {t_legacy*1e3:.1f} -> {t_shared*1e3:.1f} ms "
          f"({t_legacy/t_shared:.2f}x), PCG iters unchanged "
          f"({res_shared.iters})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--csv", default="")
    args = ap.parse_args()
    run(dry_run=args.dry_run)
    if args.csv:
        flush_csv(args.csv)


if __name__ == "__main__":
    main()
