"""Distributed SpTRSV: collective count/bytes vs rewriting (the paper's
barrier-removal story at pod scale — each level boundary is one collective).

Runs on 8 virtual CPU devices; reports per-solve collective counts & bytes
for the two exchange strategies (psum = naive full-vector barrier port,
all_gather = value-only exchange) with and without equation rewriting, plus
wall time.  The multi-chip roofline projection of the same schedule lives in
EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import RewriteConfig, SpTRSV
from repro.core.dist import shard_schedule
from repro.core.codegen import build_schedule
from repro.launch.mesh import make_mesh
from repro.sparse import lung2_like

from .common import emit, timeit


def run(full_scale: bool = True):
    print("== dist_solve: level collectives with/without rewriting ==")
    mesh = make_mesh((8,), ("data",))
    L = lung2_like(scale=0.25 if full_scale else 0.05, dtype=np.float32)
    b = jnp.asarray(np.random.default_rng(0).normal(size=L.n).astype(np.float32))

    for label, rw in (("base", None),
                      ("rewrite", RewriteConfig(thin_threshold=2))):
        for strat in ("psum", "all_gather"):
            s = SpTRSV.build(L, strategy="distributed", mesh=mesh,
                             dist_strategy=strat, rewrite=rw)
            target = s.rewrite_result.L if s.rewrite_result else L
            sched = build_schedule(target)
            d = shard_schedule(sched, 8)
            t = timeit(s.solve, b, iters=3, warmup=1)
            emit(f"dist.{label}.{strat}.levels", d.num_levels,
                 note="= collectives/solve")
            emit(f"dist.{label}.{strat}.bytes", d.collective_bytes(4, strat),
                 "B/solve")
            emit(f"dist.{label}.{strat}.ms", f"{t*1e3:.2f}", "ms")
    return True


if __name__ == "__main__":
    run()
